"""MS102: re-seeding inside a function body.

The PR 2 bug class: ``UNetEstimator.measure_mps`` re-seeded its RNG to 0
on *every call*, silently collapsing profiling noise to a constant.  Seeds
belong at module top level or in a CLI ``main`` — a ``*.seed(...)`` call,
``np.random.seed``, or a ``PRNGKey(<constant>)`` buried inside any other
function makes every caller share one hidden stream reset.

``PRNGKey(x)`` with a *variable* argument is fine (the seed was threaded
in); only constant literals are flagged.  Test files are exempt: a fixed
key inside a test is the correct pattern, not a bug.
"""
from __future__ import annotations

import ast
from typing import List

from misolint.context import ModuleContext
from misolint.rules.base import Finding, Rule, register_rule

_CLI_FUNC_NAMES = {"main", "_main", "cli"}


def _is_const(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return _is_const(node.operand)
    if isinstance(node, ast.BinOp):     # e.g. PRNGKey(0x5EED + 1)
        return _is_const(node.left) and _is_const(node.right)
    return False


@register_rule
class ReseedRule(Rule):
    id = "MS102"
    title = "re-seeding inside a function (seed at module/CLI top level)"
    scope = ("src/",)

    def applies_to(self, path: str) -> bool:
        if not super().applies_to(path):
            return False
        name = path.rsplit("/", 1)[-1]
        return not (name.startswith("test_") or name == "conftest.py")

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = ctx.enclosing(node, ast.FunctionDef, ast.AsyncFunctionDef)
            if fn is None or fn.name in _CLI_FUNC_NAMES:
                continue
            dotted = ctx.resolve(node.func) or ""
            if dotted.endswith(".seed") or dotted == "seed":
                out.append(self.finding(
                    ctx, node,
                    f"`{dotted}(...)` inside `{fn.name}`: re-seeding in a "
                    f"function resets a shared stream on every call; seed "
                    f"once at module/CLI top level and thread the "
                    f"Generator/key"))
            elif (dotted.split(".")[-1] == "PRNGKey" and node.args
                    and _is_const(node.args[0])):
                out.append(self.finding(
                    ctx, node,
                    f"constant PRNGKey({ast.unparse(node.args[0])}) inside "
                    f"`{fn.name}`: every call replays the same stream; "
                    f"accept a key/seed parameter instead"))
        return out
