"""MS107: naive float accumulation in simulator hot loops.

The engine's index invariants (``WorkAggregate`` vs. exact recompute,
energy integrals, per-component profile clocks) only hold bit-for-bit
because accumulation sites are deliberate.  A bare ``total += x`` in a
loop inside ``core/sim/`` accumulates rounding error that depends on
iteration count and order; the contract is to use the Kahan
:class:`~repro.core.sim.index.WorkAggregate`, ``math.fsum`` or ``np.sum``
— or to suppress with a reason when the sum is short and feeds a Kahan
aggregate anyway.

Skipped automatically: integer-literal increments (``count += 1`` event
counters) and per-item updates whose target hangs off the loop variable
(``rj.since_ckpt_t += dt`` updates each job, it does not accumulate
across them) — including through local aliases bound from the loop
variable inside the loop body (``job = rj.job; job.t_run += dt``) and
targets subscripted by the loop index (``ckw[i] += done`` writes one slot
per iteration).
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from misolint.context import ModuleContext
from misolint.rules.base import Finding, Rule, register_rule


def _root_name(node: ast.AST) -> Optional[str]:
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _target_names(target: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for n in ast.walk(target):
        if isinstance(n, ast.Name):
            out.add(n.id)
    return out


def _is_integral_literal(node: ast.AST) -> bool:
    """Integer-valued literal steps (``+= 1``, ``+= 1.0``): exact in binary
    floating point up to 2**53, so counters are not accumulation hazards."""
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        node = node.operand
    if not isinstance(node, ast.Constant):
        return False
    v = node.value
    return type(v) is int or (type(v) is float and v.is_integer())


@register_rule
class FloatAccumulationRule(Rule):
    id = "MS107"
    title = "naive `+=` float accumulation in a sim hot loop"
    scope = ("src/repro/core/sim/", "src/repro/core/simulator.py")

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.AugAssign)
                    and isinstance(node.op, ast.Add)):
                continue
            if _is_integral_literal(node.value):
                continue
            # collect enclosing loops up to the nearest function boundary
            loop_vars: Set[str] = set()
            for_nodes: List[ast.For] = []
            in_loop = False
            cur = ctx.parent(node)
            while cur is not None and not isinstance(
                    cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.Lambda, ast.Module)):
                if isinstance(cur, ast.For):
                    in_loop = True
                    loop_vars |= _target_names(cur.target)
                    for_nodes.append(cur)
                elif isinstance(cur, ast.While):
                    in_loop = True
                cur = ctx.parent(cur)
            if not in_loop:
                continue
            # names derived from the loop variable inside the loop body
            # (`job = rj.job`) update per-item state, same as the loop
            # variable itself; chase the aliases to a fixed point
            derived: Set[str] = set(loop_vars)
            changed = True
            while changed:
                changed = False
                for ln in for_nodes:
                    for sub in ast.walk(ln):
                        if not (isinstance(sub, ast.Assign)
                                and len(sub.targets) == 1
                                and isinstance(sub.targets[0], ast.Name)):
                            continue
                        name = sub.targets[0].id
                        if (name not in derived
                                and _root_name(sub.value) in derived):
                            derived.add(name)
                            changed = True
            root = _root_name(node.target)
            if root is not None and root in derived:
                continue        # per-item update, not a cross-loop sum
            if (isinstance(node.target, ast.Subscript)
                    and not isinstance(node.target.slice, ast.Slice)
                    and _root_name(node.target.slice) in derived):
                continue        # one slot per iteration, not a running sum
            out.append(self.finding(
                ctx, node,
                f"`{ast.unparse(node.target)} += ...` accumulates floats "
                f"across loop iterations; use the Kahan WorkAggregate, "
                f"math.fsum or np.sum (or suppress with a reason if the "
                f"sum is short-lived and bounded)"))
        return out
