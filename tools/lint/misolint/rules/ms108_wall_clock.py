"""MS108: wall-clock and entropy sources inside the simulation engine.

Simulated time is the only clock the engine may consult: a ``time.time()``
or ``datetime.now()`` on a decision path makes results depend on when (or
on which machine) the run happened, which no seed can reproduce.  The same
goes for ambient entropy (``os.urandom``, ``uuid.uuid4``, ``secrets.*``).

``time.perf_counter()`` is deliberately *not* flagged: it is the
designated profiling clock — its readings only ever land in the
``sim.prof`` wall-clock buckets that ``sweep --profile`` reports, never in
simulation state.  Putting a perf_counter value into sim state is exactly
what this rule exists to keep greppable, so route new timing through the
prof dict.
"""
from __future__ import annotations

import ast
from typing import List

from misolint.context import ModuleContext
from misolint.rules.base import Finding, Rule, register_rule

_BANNED = {
    "time.time": "wall-clock read",
    "time.time_ns": "wall-clock read",
    "time.monotonic": "host-monotonic clock read",
    "time.monotonic_ns": "host-monotonic clock read",
    "time.localtime": "wall-clock read",
    "datetime.datetime.now": "wall-clock read",
    "datetime.datetime.utcnow": "wall-clock read",
    "datetime.datetime.today": "wall-clock read",
    "datetime.date.today": "wall-clock read",
    "os.urandom": "ambient entropy",
    "uuid.uuid4": "ambient entropy",
    "uuid.uuid1": "host/time-derived id",
    "secrets.token_bytes": "ambient entropy",
    "secrets.token_hex": "ambient entropy",
}


@register_rule
class WallClockRule(Rule):
    id = "MS108"
    title = "wall-clock/entropy source inside the sim engine"
    scope = ("src/repro/core/sim/", "src/repro/core/simulator.py")

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = ctx.resolve(node.func) or ""
            kind = _BANNED.get(dotted)
            if kind is None and dotted:
                # `from datetime import datetime` -> datetime.datetime.now
                # resolves already; also catch bare `now()` style imports
                for full, k in _BANNED.items():
                    if dotted == full.split(".", 1)[-1]:
                        kind = k
                        break
            if kind:
                out.append(self.finding(
                    ctx, node,
                    f"{kind} `{dotted}()` inside the sim engine: simulated "
                    f"time (`sim.t`) and seeded RNG streams are the only "
                    f"admissible time/entropy sources here"))
        return out
