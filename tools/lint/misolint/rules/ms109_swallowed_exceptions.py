"""MS109: bare ``except:`` / silently swallowed exceptions in core & launch.

The robustness contract of the fault-injection layer
(``repro.core.sim.faults``) is that faults are *modeled*, never ignored: a
crash becomes a blast-radius event, a flaky reconfigure becomes a bounded
retry, an estimator blow-up degrades to last-known-good — each observable
in the robustness metrics.  A bare ``except:`` (which also eats
``KeyboardInterrupt``/``SystemExit``) or a broad handler whose body only
``pass``es silently deletes a failure mode instead, producing simulations
that look healthy while hiding corrupted state.

Flagged inside ``src/repro/core/`` and ``src/repro/launch/``:

* any bare ``except:`` handler, whatever its body;
* an ``except``-anything handler (``Exception``/``BaseException`` or a
  tuple containing one) whose body is only ``pass``/``...``/``continue``.

Narrow intentional gates (``except ImportError: pass`` around optional
deps) stay allowed; genuinely intentional broad swallows get a
``# misolint: disable=MS109 -- why`` suppression or a baseline entry.
"""
from __future__ import annotations

import ast
from typing import List

from misolint.context import ModuleContext
from misolint.rules.base import Finding, Rule, register_rule

_BROAD = {"Exception", "BaseException"}


def _is_broad(ctx: ModuleContext, exc: ast.expr) -> bool:
    """Whether the handler's exception expression catches everything."""
    if isinstance(exc, ast.Tuple):
        return any(_is_broad(ctx, e) for e in exc.elts)
    dotted = ctx.resolve(exc) or ""
    return dotted.rsplit(".", 1)[-1] in _BROAD


def _swallows(body: List[ast.stmt]) -> bool:
    """Whether the handler body discards the exception without acting on
    it: nothing but ``pass`` / ``...`` / ``continue``."""
    for stmt in body:
        if isinstance(stmt, (ast.Pass, ast.Continue)):
            continue
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant) \
                and stmt.value.value is Ellipsis:
            continue
        return False
    return True


@register_rule
class SwallowedExceptionRule(Rule):
    id = "MS109"
    title = "bare except / silently swallowed exception"
    scope = ("src/repro/core/", "src/repro/launch/")

    def check(self, ctx: ModuleContext) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                out.append(self.finding(
                    ctx, node,
                    "bare `except:` swallows everything including "
                    "KeyboardInterrupt/SystemExit; catch the narrowest "
                    "exception the failure mode can raise (robustness "
                    "contract: faults are modeled, never ignored)"))
            elif _is_broad(ctx, node.type) and _swallows(node.body):
                out.append(self.finding(
                    ctx, node,
                    "broad exception handler whose body only passes: the "
                    "failure mode is silently deleted instead of modeled, "
                    "recorded or re-raised (robustness contract of the "
                    "fault-injection layer)"))
        return out
