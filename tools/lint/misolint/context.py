"""Per-file lint context: parsed AST, source lines, comment suppressions
and cheap module-level facts shared by every rule.

Suppression grammar (comments only, so it never affects runtime):

    x = risky()            # misolint: disable=MS103 -- reason why it is ok
    # misolint: disable=MS103,MS107 -- reason (applies to the NEXT line)
    # misolint: disable-file=MS102 -- reason (whole file, any position)

The reason string after ``--`` is mandatory: a suppression without one is
itself reported (rule id ``MS000``), so "just silence it" leaves a trail.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

_DISABLE_RE = re.compile(
    r"#\s*misolint:\s*(disable(?:-file)?)\s*=\s*"
    r"(MS\d{3}(?:\s*,\s*MS\d{3})*)\s*(?:--\s*(.*\S))?\s*$")


@dataclass
class Suppression:
    line: int                 # line the comment sits on (1-based)
    rules: Tuple[str, ...]
    reason: Optional[str]
    file_level: bool
    used: bool = False


@dataclass
class ModuleContext:
    """Everything a rule needs to inspect one Python file."""
    path: str                 # normalized, repo-relative (forward slashes)
    source: str
    tree: ast.AST
    lines: List[str]
    suppressions: List[Suppression] = field(default_factory=list)
    # local name -> dotted module it refers to ("np" -> "numpy",
    # "ProcessPoolExecutor" -> "concurrent.futures.ProcessPoolExecutor")
    imports: Dict[str, str] = field(default_factory=dict)
    parents: Dict[ast.AST, ast.AST] = field(default_factory=dict)

    # ------------------------------------------------------------ queries

    def imports_module(self, dotted: str) -> bool:
        """True if the file imports ``dotted`` (or a submodule of it) at
        any level, including inside functions."""
        prefix = dotted + "."
        return any(m == dotted or m.startswith(prefix)
                   for m in self.imports.values())

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Resolve a Name/Attribute chain to its dotted origin, expanding
        import aliases: ``np.random.rand`` -> ``numpy.random.rand``."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.imports.get(node.id, node.id)
        parts.append(root)
        return ".".join(reversed(parts))

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self.parents.get(node)

    def enclosing(self, node: ast.AST, *types) -> Optional[ast.AST]:
        """Nearest ancestor of one of ``types`` (not counting node)."""
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, types):
                return cur
            cur = self.parents.get(cur)
        return None

    def _comment_only(self, line: int) -> bool:
        if not (1 <= line <= len(self.lines)):
            return False
        stripped = self.lines[line - 1].strip()
        return stripped.startswith("#")

    def suppressed(self, rule_id: str, line: int) -> Optional[Suppression]:
        """The suppression covering (rule, line), if any; marks it used.

        A directive covers its own line (inline comments) and the next
        statement below it — intervening comment-only lines are skipped, so
        a multi-line reason can continue in plain comments under the
        directive."""
        for s in self.suppressions:
            if rule_id not in s.rules:
                continue
            covered = s.file_level or s.line == line
            if not covered and s.line < line:
                covered = all(self._comment_only(i)
                              for i in range(s.line + 1, line))
            if covered:
                s.used = True
                return s
        return None


def _collect_imports(tree: ast.AST) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    out[a.asname] = a.name
                else:
                    # `import a.b` binds `a` locally but still imports a.b;
                    # the sentinel key keeps the full path visible to
                    # imports_module() without shadowing a real binding
                    out[a.name.split(".")[0]] = a.name.split(".")[0]
                    out["\x00import:" + a.name] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def _collect_suppressions(source: str) -> List[Suppression]:
    sups: List[Suppression] = []
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            m = _DISABLE_RE.search(tok.string)
            if not m:
                continue
            rules = tuple(r.strip() for r in m.group(2).split(","))
            sups.append(Suppression(
                line=tok.start[0], rules=rules, reason=m.group(3),
                file_level=(m.group(1) == "disable-file")))
    except tokenize.TokenizeError:
        pass
    return sups


def build_context(path: str, source: str) -> ModuleContext:
    tree = ast.parse(source, filename=path)
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return ModuleContext(
        path=path.replace("\\", "/"),
        source=source,
        tree=tree,
        lines=source.splitlines(),
        suppressions=_collect_suppressions(source),
        imports=_collect_imports(tree),
        parents=parents,
    )
