"""Command-line driver: ``python -m misolint [paths...]``.

Exit codes: 0 clean (after suppressions + baseline), 1 new findings,
2 usage or parse errors.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional, Sequence, Tuple

from misolint.api import __version__, lint_paths, ruleset_hash
from misolint.baseline import (Baseline, DEFAULT_BASELINE, fingerprint,
                               make_entries)
from misolint.fixes import fix_source
from misolint.rules import all_rules


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="misolint",
        description="determinism & simulator-invariant static analysis "
                    "(rules MS101..MS110; see tools/lint/misolint/rules/)")
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files/directories to lint (default: src tests)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline JSON path (default: {DEFAULT_BASELINE} "
                         f"when it exists)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline file (report everything)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the current findings to the baseline file "
                         "and exit 0")
    ap.add_argument("--fix", action="store_true",
                    help="apply mechanical fixes (MS103 sorted() wrap, "
                         "MS105 None+guard) in place, then re-lint")
    ap.add_argument("--diff", metavar="GIT_REF", default=None,
                    help="diff-aware mode: only report findings in files "
                         "changed vs GIT_REF (e.g. origin/main)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also list suppressed/baselined findings in text "
                         "output")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    ap.add_argument("--version", action="store_true",
                    help="print version + rule-set hash and exit")
    return ap


def _changed_files(ref: str) -> Optional[List[str]]:
    try:
        out = subprocess.run(
            ["git", "diff", "--name-only", "--diff-filter=dR", ref, "--"],
            capture_output=True, text=True, check=True)
    except (OSError, subprocess.CalledProcessError) as exc:
        print(f"misolint: --diff {ref}: git diff failed: {exc}",
              file=sys.stderr)
        return None
    return [l.strip().replace(os.sep, "/")
            for l in out.stdout.splitlines() if l.strip()]


def _run_fix(paths: Sequence[str]) -> int:
    from misolint.api import _iter_py_files
    from misolint.context import build_context
    n_total = 0
    for fpath in _iter_py_files(paths):
        with open(fpath, encoding="utf-8") as fh:
            source = fh.read()
        try:
            ctx = build_context(fpath.replace(os.sep, "/"), source)
        except SyntaxError:
            continue
        new_source, n = fix_source(ctx)
        if n:
            with open(fpath, "w", encoding="utf-8") as fh:
                fh.write(new_source)
            print(f"misolint: fixed {n} finding(s) in {fpath}")
            n_total += n
    print(f"misolint: --fix applied {n_total} fix(es); re-run the golden "
          f"trace tests before committing")
    return n_total


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    rs_hash = ruleset_hash()

    if args.version:
        print(f"misolint {__version__} (ruleset {rs_hash})")
        return 0
    if args.list_rules:
        for cls in all_rules():
            scope = ", ".join(cls.scope) if cls.scope else "everywhere"
            fx = "  [--fix]" if cls.fixable else ""
            print(f"{cls.id}  {cls.title}  ({scope}){fx}")
        return 0

    select = ([r.strip() for r in args.select.split(",") if r.strip()]
              if args.select else None)

    if args.fix:
        _run_fix(args.paths)

    pairs, errors = lint_paths(args.paths, select=select)
    for err in errors:
        print(f"misolint: error: {err}", file=sys.stderr)

    # fingerprint everything once (baseline matching + --write-baseline)
    fps: List[Tuple] = [(f, fingerprint(f, ctx.lines)) for f, ctx in pairs]

    baseline_path = args.baseline or (
        DEFAULT_BASELINE if os.path.exists(DEFAULT_BASELINE) else None)
    baseline = None
    if baseline_path and not args.no_baseline and not args.write_baseline \
            and os.path.exists(baseline_path):
        baseline = Baseline.load(baseline_path)
        if baseline.ruleset and baseline.ruleset != rs_hash:
            print(f"misolint: warning: baseline {baseline_path} was written "
                  f"for ruleset {baseline.ruleset}, current is {rs_hash} — "
                  f"regenerate with --write-baseline after triage",
                  file=sys.stderr)

    if args.diff is not None:
        changed = _changed_files(args.diff)
        if changed is None:
            return 2
        changed_set = set(changed)
        fps = [(f, fp) for f, fp in fps if f.path in changed_set]

    if args.write_baseline:
        active = [(f, fp) for f, fp in fps if not f.suppressed]
        path = args.baseline or DEFAULT_BASELINE
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        Baseline().save(path, make_entries(active), rs_hash)
        print(f"misolint: wrote {len(active)} finding(s) "
              f"({len(make_entries(active))} fingerprints) to {path}")
        return 0

    # classify: suppressed / baselined / new
    unsuppressed = [(f, fp) for f, fp in fps if not f.suppressed]
    if baseline is not None:
        tagged = baseline.filter(unsuppressed)
    else:
        tagged = [(f, False) for f, _ in unsuppressed]
    new = [f for f, base in tagged if not base]
    baselined = [f for f, base in tagged if base]
    suppressed = [f for f, _ in fps if f.suppressed]

    if args.format == "json":
        doc = {
            "version": __version__,
            "ruleset": rs_hash,
            "baseline": baseline_path if baseline is not None else None,
            "counts": {"new": len(new), "baselined": len(baselined),
                       "suppressed": len(suppressed),
                       "errors": len(errors)},
            "findings": [
                {"rule": f.rule, "path": f.path, "line": f.line,
                 "col": f.col, "message": f.message,
                 "status": ("baselined" if base else "new")}
                for f, base in tagged
            ] + [
                {"rule": f.rule, "path": f.path, "line": f.line,
                 "col": f.col, "message": f.message, "status": "suppressed",
                 "reason": f.suppress_reason}
                for f in suppressed
            ],
            "parse_errors": errors,
        }
        print(json.dumps(doc, indent=1))
    else:
        for f in new:
            print(f"{f.path}:{f.line}:{f.col + 1}: {f.rule} {f.message}")
        if args.show_suppressed:
            for f in baselined:
                print(f"{f.path}:{f.line}:{f.col + 1}: {f.rule} "
                      f"[baselined] {f.message}")
            for f in suppressed:
                reason = f.suppress_reason or "(no reason)"
                print(f"{f.path}:{f.line}:{f.col + 1}: {f.rule} "
                      f"[suppressed: {reason}] {f.message}")
        tail = (f"{len(new)} finding(s)"
                f" ({len(baselined)} baselined, {len(suppressed)} "
                f"suppressed; ruleset {rs_hash})")
        print(f"misolint: {tail}" if new or baselined or suppressed
              else f"misolint: clean (ruleset {rs_hash})")

    if errors:
        return 2
    return 1 if new else 0
