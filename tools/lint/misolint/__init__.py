"""misolint — determinism & simulator-invariant static analysis for MISO.

Every headline number this repo reports (JCT deltas, energy, the 5,000-GPU
trace replay) rests on *bit-identical, deterministic* simulation.  misolint
encodes that contract as eight mechanical AST checks (MS101..MS108) so the
violations that burned review time in past PRs — re-seed-to-0 inside a
measurement call, fork-after-jax pool deadlocks, hash-ordered set iteration
feeding placement — fail CI instead of reaching reviewers.

Run it from the repo root (the package is importable both via the repo's
standard ``PYTHONPATH=src`` and via ``PYTHONPATH=tools/lint``)::

    PYTHONPATH=src python -m misolint src/ tests/
    PYTHONPATH=src python -m misolint --format json src/
    PYTHONPATH=src python -m misolint --fix src/        # MS103/MS105 autofix
    PYTHONPATH=src python -m misolint --write-baseline src/ tests/

Suppress an intentional finding inline (same line or the line above), with
a mandatory reason after ``--``::

    params, _ = init(jax.random.PRNGKey(0), ...)  # misolint: disable=MS102 -- shape-only jit warmup

See ``misolint/rules/`` for one module per rule and ``README.md`` ("Static
analysis") for how to add a rule or regenerate the baseline.
"""
from misolint.api import (Finding, lint_paths, lint_source, ruleset_hash,
                          __version__)

__all__ = ["Finding", "lint_paths", "lint_source", "ruleset_hash",
           "__version__"]
