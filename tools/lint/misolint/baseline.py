"""Committed baseline of grandfathered findings.

CI must fail on *new* violations only, so findings already present when a
rule landed are recorded here and filtered out.  Fingerprints are content-
addressed — ``sha1(rule \\x00 path \\x00 stripped-source-line)`` with a
per-fingerprint count — so the baseline survives unrelated line-number
drift but expires the moment the offending line is edited (which is the
point: touching the line means you own the finding).

Regenerate (after triaging!) with::

    PYTHONPATH=src python -m misolint --write-baseline src/ tests/
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Dict, List, Optional, Tuple

from misolint.rules.base import Finding

BASELINE_VERSION = 1
DEFAULT_BASELINE = os.path.join("tools", "lint", "misolint_baseline.json")


def fingerprint(f: Finding, lines: Optional[List[str]] = None,
                line_text: Optional[str] = None) -> str:
    if line_text is None:
        if lines and 1 <= f.line <= len(lines):
            line_text = lines[f.line - 1]
        else:
            line_text = ""
    h = hashlib.sha1()
    h.update(f.rule.encode())
    h.update(b"\x00")
    h.update(f.path.encode())
    h.update(b"\x00")
    h.update(line_text.strip().encode())
    return h.hexdigest()[:16]


class Baseline:
    def __init__(self, counts: Optional[Dict[str, int]] = None,
                 ruleset: str = "", notes: Optional[Dict[str, str]] = None):
        self.counts = dict(counts or {})
        self.ruleset = ruleset
        self.notes = dict(notes or {})   # fingerprint -> human context

    @classmethod
    def load(cls, path: str) -> "Baseline":
        with open(path) as fh:
            raw = json.load(fh)
        if raw.get("version") != BASELINE_VERSION:
            raise ValueError(f"baseline {path}: unsupported version "
                             f"{raw.get('version')!r}")
        counts = {e["fingerprint"]: int(e.get("count", 1))
                  for e in raw.get("findings", [])}
        notes = {e["fingerprint"]: e["note"]
                 for e in raw.get("findings", []) if e.get("note")}
        return cls(counts, raw.get("ruleset", ""), notes)

    def save(self, path: str, entries: List[dict], ruleset: str) -> None:
        doc = {
            "version": BASELINE_VERSION,
            "ruleset": ruleset,
            "findings": entries,
        }
        with open(path, "w") as fh:
            json.dump(doc, fh, indent=1, sort_keys=False)
            fh.write("\n")

    def filter(self, findings: List[Tuple[Finding, str]]
               ) -> List[Tuple[Finding, bool]]:
        """Tag each (finding, fingerprint) as baselined or new, consuming
        baseline budget per fingerprint."""
        budget = dict(self.counts)
        out: List[Tuple[Finding, bool]] = []
        for f, fp in findings:
            if budget.get(fp, 0) > 0:
                budget[fp] -= 1
                out.append((f, True))
            else:
                out.append((f, False))
        return out


def make_entries(findings: List[Tuple[Finding, str]],
                 notes: Optional[Dict[str, str]] = None) -> List[dict]:
    """Aggregate (finding, fingerprint) pairs into committed-baseline rows,
    sorted for stable diffs."""
    agg: Dict[str, dict] = {}
    for f, fp in findings:
        e = agg.setdefault(fp, {"fingerprint": fp, "rule": f.rule,
                                "path": f.path, "count": 0,
                                "example_line": f.line,
                                "message": f.message})
        e["count"] += 1
        e["example_line"] = min(e["example_line"], f.line)
    for fp, note in (notes or {}).items():
        if fp in agg:
            agg[fp]["note"] = note
    return sorted(agg.values(),
                  key=lambda e: (e["path"], e["rule"], e["fingerprint"]))
