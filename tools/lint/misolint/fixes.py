"""``--fix``: mechanical rewrites for the rules where the fix is a pure
syntax transformation.

* MS103 — wrap the offending set-valued iterable in ``sorted(...)``.
* MS105 — mutable default ``=[]``/``={}``/``=set()`` becomes ``=None`` plus
  an ``if arg is None: arg = <original>`` guard after the docstring.

Both rewrites change *behavior* only where the code was already
order-dependent or sharing state — which is why the workflow is: run
``--fix``, re-run the golden-trace tests, and only keep fixes that stay
bit-identical (regenerate the baseline with a justification otherwise).
Suppressed findings are never auto-fixed.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Tuple

from misolint.context import ModuleContext
from misolint.rules.ms103_set_iteration import SetIterationRule
from misolint.rules.ms105_mutable_default import MutableDefaultRule, \
    is_mutable_default


def _offsets(source: str) -> List[int]:
    """Absolute offset of the start of each 1-based line."""
    offs = [0]
    for line in source.splitlines(keepends=True):
        offs.append(offs[-1] + len(line))
    return offs


def _abs(offs: List[int], line: int, col: int) -> int:
    return offs[line - 1] + col


class _Edit:
    __slots__ = ("start", "end", "text")

    def __init__(self, start: int, end: int, text: str):
        self.start, self.end, self.text = start, end, text


def _apply(source: str, edits: List[_Edit]) -> str:
    for e in sorted(edits, key=lambda e: e.start, reverse=True):
        source = source[:e.start] + e.text + source[e.end:]
    return source


def _node_span(offs: List[int], node: ast.AST) -> Optional[Tuple[int, int]]:
    if getattr(node, "end_lineno", None) is None:
        return None
    return (_abs(offs, node.lineno, node.col_offset),
            _abs(offs, node.end_lineno, node.end_col_offset))


def _ms103_edits(ctx: ModuleContext,
                 offs: List[int]) -> Tuple[List[_Edit], int]:
    rule = SetIterationRule()
    if not rule.applies_to(ctx.path):
        return [], 0
    edits: List[_Edit] = []
    seen = set()
    for f in rule.check(ctx):
        if ctx.suppressed(f.rule, f.line):
            continue
        # relocate the flagged expression node from the finding position
        for node in ast.walk(ctx.tree):
            if (getattr(node, "lineno", None) == f.line
                    and getattr(node, "col_offset", None) == f.col
                    and isinstance(node, (ast.Call, ast.Set, ast.SetComp,
                                          ast.BinOp))):
                span = _node_span(offs, node)
                if span and span not in seen:
                    seen.add(span)
                    edits.append(_Edit(span[0], span[0], "sorted("))
                    edits.append(_Edit(span[1], span[1], ")"))
                break
    return edits, len(seen)


def _ms105_edits(ctx: ModuleContext,
                 offs: List[int]) -> Tuple[List[_Edit], int]:
    rule = MutableDefaultRule()
    if not rule.applies_to(ctx.path):
        return [], 0
    edits: List[_Edit] = []
    n_fixed = 0
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        args = node.args
        pos = args.posonlyargs + args.args
        pairs = list(zip(pos[len(pos) - len(args.defaults):], args.defaults))
        pairs += [(a, d) for a, d in zip(args.kwonlyargs, args.kw_defaults)
                  if d is not None]
        guards: List[Tuple[str, str]] = []
        for arg, default in pairs:
            if not is_mutable_default(default):
                continue
            if ctx.suppressed("MS105", default.lineno):
                continue
            span = _node_span(offs, default)
            if span is None or default.lineno != default.end_lineno:
                continue        # multi-line defaults: fix by hand
            src = ctx.source[span[0]:span[1]]
            edits.append(_Edit(span[0], span[1], "None"))
            guards.append((arg.arg, src))
        if not guards or not node.body:
            continue
        # insert guards after the docstring (or at the body start)
        body = node.body
        first = body[0]
        anchor = first
        if (isinstance(first, ast.Expr)
                and isinstance(first.value, ast.Constant)
                and isinstance(first.value.value, str) and len(body) > 1):
            anchor = body[1]
        indent = " " * anchor.col_offset
        at = _abs(offs, anchor.lineno, 0)
        text = "".join(f"{indent}if {name} is None:\n"
                       f"{indent}    {name} = {src}\n"
                       for name, src in guards)
        edits.append(_Edit(at, at, text))
        n_fixed += len(guards)
    return edits, n_fixed


def fix_source(ctx: ModuleContext) -> Tuple[str, int]:
    """Apply MS103/MS105 fixes to one module; returns (new_source,
    n_findings_fixed). Non-overlapping by construction (distinct spans)."""
    offs = _offsets(ctx.source)
    e103, n103 = _ms103_edits(ctx, offs)
    e105, n105 = _ms105_edits(ctx, offs)
    if not e103 and not e105:
        return ctx.source, 0
    return _apply(ctx.source, e103 + e105), n103 + n105
