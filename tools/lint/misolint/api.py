"""Programmatic entry points: lint a source string, lint paths, hash the
rule set.

``lint_source`` / ``lint_paths`` return findings with suppression state
already resolved (inline ``# misolint: disable=...`` comments consumed;
suppressions *without* a reason string surface as MS000 findings so silent
mutings are impossible).  Baseline filtering is layered on top by the CLI
— the API returns everything so tests can assert on raw rule behavior.
"""
from __future__ import annotations

import ast
import hashlib
import os
from typing import Iterable, List, Optional, Sequence, Tuple

from misolint.context import ModuleContext, build_context
from misolint.rules import all_rules
from misolint.rules.base import Finding

__version__ = "1.0.0"

_SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", ".mypy_cache",
              "node_modules", ".venv", "venv", "build", "dist", ".eggs"}


def ruleset_hash() -> str:
    """Stable 12-hex digest of the active rule set: ids, titles, scopes and
    the rule modules' source text.  Stamped into sweep reports
    (``lint_version``) so benchmark JSONs record which determinism contract
    they were produced under."""
    h = hashlib.sha256()
    rules_dir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "rules")
    for name in sorted(os.listdir(rules_dir)):
        if name.endswith(".py"):
            with open(os.path.join(rules_dir, name), "rb") as fh:
                h.update(name.encode())
                h.update(b"\x00")
                h.update(fh.read())
                h.update(b"\x00")
    return h.hexdigest()[:12]


def _iter_py_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs if d not in _SKIP_DIRS)
                for name in sorted(files):
                    if name.endswith(".py"):
                        yield os.path.join(root, name)


def _relpath(path: str, root: Optional[str]) -> str:
    rel = os.path.relpath(path, root) if root else path
    return rel.replace(os.sep, "/").removeprefix("./")


def lint_context(ctx: ModuleContext,
                 select: Optional[Sequence[str]] = None) -> List[Finding]:
    findings: List[Finding] = []
    for rule_cls in all_rules():
        if select and rule_cls.id not in select:
            continue
        rule = rule_cls()
        if not rule.applies_to(ctx.path):
            continue
        findings.extend(rule.check(ctx))
    # resolve inline suppressions
    resolved: List[Finding] = []
    for f in sorted(findings, key=lambda f: f.sort_key):
        sup = ctx.suppressed(f.rule, f.line)
        if sup is not None:
            resolved.append(Finding(
                rule=f.rule, path=f.path, line=f.line, col=f.col,
                message=f.message, suppressed=True,
                suppress_reason=sup.reason))
        else:
            resolved.append(f)
    # a suppression that never fired, or fired without a reason, is itself
    # a finding: reasons are the audit trail the contract depends on
    for sup in ctx.suppressions:
        if sup.used and not sup.reason:
            resolved.append(Finding(
                rule="MS000", path=ctx.path, line=sup.line, col=0,
                message=(f"suppression of {','.join(sup.rules)} has no "
                         f"reason: append `-- <why this is safe>`")))
        elif not sup.used:
            resolved.append(Finding(
                rule="MS000", path=ctx.path, line=sup.line, col=0,
                message=(f"unused suppression of {','.join(sup.rules)}: "
                         f"nothing fires here any more — delete it")))
    return sorted(resolved, key=lambda f: f.sort_key)


def lint_source(source: str, path: str = "<string>",
                select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint one source string (fixture-test entry point)."""
    return lint_context(build_context(path, source), select)


def lint_paths(paths: Sequence[str], root: Optional[str] = None,
               select: Optional[Sequence[str]] = None,
               ) -> Tuple[List[Tuple[Finding, ModuleContext]], List[str]]:
    """Lint files/directories. Returns (findings with their contexts,
    unparseable-file errors).  Paths in findings are relative to ``root``
    (default: the current working directory)."""
    results: List[Tuple[Finding, ModuleContext]] = []
    errors: List[str] = []
    for fpath in _iter_py_files(paths):
        try:
            with open(fpath, encoding="utf-8") as fh:
                source = fh.read()
        except OSError as exc:
            errors.append(f"{fpath}: unreadable: {exc}")
            continue
        rel = _relpath(fpath, root)
        try:
            ctx = build_context(rel, source)
        except SyntaxError as exc:
            errors.append(f"{rel}:{exc.lineno}: syntax error: {exc.msg}")
            continue
        for f in lint_context(ctx, select):
            results.append((f, ctx))
    return results, errors
