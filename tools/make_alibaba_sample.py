"""Regenerate ``src/repro/data/alibaba_v2020_sample.csv``.

The committed sample mirrors the Alibaba ``cluster-trace-gpu-v2020`` per-job
schema (see ``repro.core.traces_alibaba.ALIBABA_COLUMNS``) with empirical
shapes taken from the published trace analyses: plan_gpu concentrated on
{25, 50, 100} percent with a multi-GPU tail, lognormal durations with a
minutes-scale median and an hours-scale tail, bursty submissions over a
~6 h window, and a small fraction of unfinished / malformed rows so the
loader's row accounting stays exercised by the committed file.

  PYTHONPATH=src python tools/make_alibaba_sample.py
"""
import os

import numpy as np

OUT = os.path.join(os.path.dirname(__file__), "..", "src", "repro", "data",
                   "alibaba_v2020_sample.csv")

N = 220
rng = np.random.default_rng(20200910)          # trace release date

TASKS = np.asarray(["worker", "tensorflow", "ps", "evaluator", "chief"])
TASK_P = np.asarray([0.45, 0.30, 0.12, 0.08, 0.05])
GPU_TYPES = np.asarray(["V100", "P100", "T4", "MISC"])
GPU_P = np.asarray([0.4, 0.25, 0.25, 0.1])
PLAN_GPU = np.asarray([25, 50, 100, 200, 400])
PLAN_P = np.asarray([0.33, 0.27, 0.30, 0.07, 0.03])


def main():
    rows = []
    t = 0.0
    for i in range(N):
        # bursty submissions: occasional gang of near-simultaneous jobs
        if rng.random() < 0.18:
            gap = float(rng.exponential(2.0))
        else:
            gap = float(rng.exponential(120.0))
        t += gap
        submit = int(t)                         # integer timestamps, like
        plan_gpu = int(rng.choice(PLAN_GPU, p=PLAN_P))   # the real trace
        task = str(rng.choice(TASKS, p=TASK_P))
        # joint shape: bigger requests run longer (multi-GPU training jobs)
        mean = 6.3 + 0.5 * np.log(plan_gpu / 25.0)
        dur = float(np.clip(rng.lognormal(mean=mean, sigma=1.2), 45, 42000))
        status = "Terminated"
        end = submit + int(max(dur, 1))
        if rng.random() < 0.04:                 # unfinished rows: end == 0
            status, end = "Running", 0
        inst = 1
        if task in ("worker", "ps") and rng.random() < 0.25:
            inst = int(rng.integers(2, 9))
        plan_cpu = int(rng.choice([600, 1200, 2400]))
        plan_mem = round(float(rng.uniform(10, 120)), 2)
        gpu_type = str(rng.choice(GPU_TYPES, p=GPU_P))
        rows.append(f"job_{i:04d},{task},{inst},{status},{submit},{end},"
                    f"{plan_cpu},{plan_mem},{plan_gpu},{gpu_type}")
    lines = ["job_name,task_name,inst_num,status,start_time,end_time,"
             "plan_cpu,plan_mem,plan_gpu,gpu_type"]
    lines += rows
    # two deliberately broken rows: the loader must skip + count them even
    # in the committed sample (regression for the malformed-row path)
    lines.append("job_short,worker,1,Terminated,100")
    lines.append("job_nan,worker,one,Terminated,100,200,600,32,50,T4")
    with open(OUT, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {len(rows)} data rows (+header, +2 malformed) -> {OUT}")


if __name__ == "__main__":
    main()
